"""Energy-aware autotuning sweep (§Autotune, docs/autotune.md).

Runs ``launch.solve --autotune`` end to end — model pruning, measured
trials, cache — on the power-law stress matrix and a 7-point Poisson cube,
under the ``energy`` and ``time`` objectives, and HARD-ASSERTS the
subsystem's acceptance invariants:

* the chosen config's measured ledger energy is <= the untuned
  ELL/hs/no-overlap reference's (the tuner can only win, never lose);
* the chosen config is not the out-of-the-box default (there is headroom
  to find on these problems: HYB on the power-law row-length skew, DVFS
  on the memory-bound iteration);
* the ``energy`` and ``time`` objectives can disagree (both picks are
  recorded in the gated ledger — on memory-bound problems ``energy``
  downclocks, ``time`` has no reason to);
* a second invocation against the same cache is served without running a
  single trial (``candidates_trialed == 0``) and picks the same config.

Everything gated is deterministic: chosen labels, candidate counts,
iteration counts, and modeled energies from executed traces. Baseline:
``benchmarks/baselines/autotune_smoke.json``.
"""

from __future__ import annotations

import os
import shutil
import tempfile

from benchmarks.common import run_api_solve, write_results
from repro.api import ProblemSpec, SolverConfig

OBJECTIVES = ("energy", "time")


def _problem_spec(matrix: str, shards: int, smoke: bool) -> ProblemSpec:
    if matrix == "powerlaw":
        return ProblemSpec(problem="powerlaw",
                           scale=0.01 if smoke else 0.05, shards=shards)
    if matrix == "poisson7":
        return ProblemSpec(problem="poisson7",
                           side=10 if smoke else 16, shards=shards)
    raise ValueError(matrix)


def _total_energy(led: dict) -> float:
    tot = led["solvers"]["BCMGX-analog"]["totals"]
    return tot["te_gpu"] + tot["te_cpu"]


def run_sweep(
    matrices=("powerlaw", "poisson7"), shards: int = 2, smoke: bool = True,
    budget: int = 4, maxiter: int = 200,
) -> list[dict]:
    rows = []
    picks: dict[tuple, str] = {}  # (matrix, objective) -> chosen label
    cache_dir = tempfile.mkdtemp(prefix="autotune_bench_")
    try:
        for matrix in matrices:
            spec = _problem_spec(matrix, shards, smoke)
            # untuned reference: ELL / hs / serialized / nominal frequency
            _, ref = run_api_solve(
                spec, SolverConfig(overlap=False, maxiter=maxiter)
            )
            ref_e = _total_energy(ref)
            rows.append(
                dict(
                    figure="autotune_ref", matrix=matrix, n_shards=shards,
                    chosen="ell/hs/ser/f1",
                    iters=ref["solvers"]["BCMGX-analog"]["iters"],
                    energy_j=ref_e,
                    wall_s=ref["solvers"]["BCMGX-analog"]["wall_s"],
                )
            )
            for objective in OBJECTIVES:
                cache = os.path.join(cache_dir, f"{matrix}_{objective}.json")
                tuned = SolverConfig(
                    autotune=True, objective=objective, tune_budget=budget,
                    tune_cache=cache, maxiter=maxiter,
                )
                for invocation in (1, 2):
                    _, led = run_api_solve(spec, tuned)
                    at = led["autotune"]
                    sol = led["solvers"]["BCMGX-analog"]
                    tuned_e = _total_energy(led)
                    row = dict(
                        figure="autotune", matrix=matrix, n_shards=shards,
                        objective=objective, invocation=invocation,
                        cached=at["cached"], chosen=at["chosen_label"],
                        candidates_total=at["candidates_total"],
                        candidates_pruned=at["candidates_pruned"],
                        candidates_trialed=at["candidates_trialed"],
                        iters=sol["iters"], energy_j=tuned_e,
                        time_model_s=sol["totals"]["runtime"],
                        wall_s=sol["wall_s"],
                    )
                    if at["trials"]:
                        best = at["trials"][0]  # sorted best-score first
                        row["predicted_energy_j"] = best["predicted_energy_j"]
                        row["measured_energy_j"] = best["measured_energy_j"]
                    rows.append(row)
                    if invocation == 1:
                        picks[(matrix, objective)] = at["chosen_label"]
                        first = at
                        # the tuner may only ever *win* against the
                        # untuned reference on its own objective
                        assert at["candidates_trialed"] > 0, (
                            f"first tuned solve ran no trials "
                            f"({matrix}/{objective})"
                        )
                        if objective == "energy":
                            # downclocking a memory-bound solve is a strict
                            # measured energy win, so the energy objective
                            # must always find headroom over the default...
                            assert at["chosen_label"] != "ell/hs/ov/f1", (
                                f"energy autotune found no headroom over "
                                f"the default ({matrix})"
                            )
                            # ...and may only ever win against the untuned
                            # serialized reference (time can pick the
                            # default when no axis helps it)
                            assert tuned_e <= ref_e, (
                                f"tuned energy {tuned_e} exceeds the untuned "
                                f"ELL/hs/no-overlap reference {ref_e} "
                                f"({matrix})"
                            )
                    else:
                        # cache-served repeat: same decision, zero trials
                        assert at["cached"], (
                            f"second invocation missed the tuning cache "
                            f"({matrix}/{objective})"
                        )
                        assert at["candidates_trialed"] == 0, (
                            f"cache-served solve still ran trials "
                            f"({matrix}/{objective})"
                        )
                        assert at["chosen_label"] == first["chosen_label"], (
                            f"cache returned a different config "
                            f"({matrix}/{objective})"
                        )
            # the objectives must be able to disagree on at least one axis
            # (energy downclocks the memory-bound iteration, time does not)
            assert (
                picks[(matrix, "energy")] != picks[(matrix, "time")]
            ), (
                f"energy and time objectives agreed on {matrix}: "
                f"{picks[(matrix, 'energy')]} — the DVFS axis found no "
                f"race-to-idle trade-off to make"
            )
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    rows = run_sweep(smoke=smoke)
    print(fmt_table(
        rows,
        [("matrix", "matrix"), ("objective", "objective"),
         ("invocation", "inv"), ("chosen", "chosen"),
         ("candidates_trialed", "trialed"), ("iters", "iters"),
         ("energy_j", "energy (J)")],
        "Autotune: chosen configs vs the untuned reference",
    ))
    write_results("autotune", rows)


if __name__ == "__main__":
    main()
