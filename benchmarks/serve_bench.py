"""Solve-as-a-service throughput/energy benchmark (§Serving).

Drives ``launch.serve_solver`` (the warm-session serving engine over
:class:`repro.api.SolverSession`) in subprocesses and HARD-ASSERTS the
acceptance invariants of the serving path:

a. **warm requests are free of setup**: every non-cold batch in the engine
   ledger reports ``new_partitions == 0`` and ``new_tune_trials == 0``;
   on the tuned leg the first invocation runs trials once (batch 0) and a
   second invocation against the same tuning cache runs ZERO trials in the
   whole process (``sessions[0].tune_trials == 0``, served from cache);
b. **batching pays**: batched ``slots=8`` warm throughput (solves per
   wall-second over warm batches) is >= 2x the sequential ``slots=1``
   warm throughput — the SpMM reads the matrix once per iteration for all
   columns — and warm throughput is >= 2x cold on the batched leg (the
   compile/partition cost is paid once);
c. **the energy ledger splits exactly**: per-request energies
   (``energy.attribution.split_block_energy``) sum back to the engine
   total within 5% (the split is exact by construction; 5% is the
   acceptance tolerance).

Gated: batch/session counters, iteration counts, modeled energies, the
invariant booleans, tuned decisions. Info: everything wall-derived
(throughput, p50/p99 latency).
"""

from __future__ import annotations

import os
import shutil
import tempfile

from benchmarks.common import run_serve_with_ledger, write_results


def _serve_args(side: int, shards: int, requests: int, slots: int,
                maxiter: int, extra=()) -> list[str]:
    return [
        "--problem", "poisson7", "--side", str(side),
        "--shards", str(shards), "--requests", str(requests),
        "--slots", str(slots), "--maxiter", str(maxiter),
    ] + list(extra)


def _leg_row(leg: str, led: dict) -> dict:
    tot = led["totals"]
    warm = [b for b in led["batches"] if not b["cold"]]
    sess = led["sessions"][0]
    tuned = led["tuned"][0] if led.get("tuned") else {}
    split_ok = (
        abs(tot["energy_requests_j"] - tot["energy_j"])
        <= 0.05 * tot["energy_j"]
    )
    return dict(
        figure="serve",
        leg=leg,
        slots=led["engine"]["slots"],
        n_requests=led["n_requests"],
        n_batches=led["n_batches"],
        cold_batches=led["cold_batches"],
        warm_batches=led["warm_batches"],
        iters=tot["iters"],
        energy_j=tot["energy_j"],
        energy_per_solve_j=tot["energy_per_solve_j"],
        session_partitions=sess["partitions"],
        session_tune_trials=sess["tune_trials"],
        warm_new_partitions=sum(b["new_partitions"] for b in warm),
        warm_new_tune_trials=sum(b["new_tune_trials"] for b in warm),
        energy_split_ok=split_ok,
        chosen=tuned.get("tuned_label") or "-",
        tune_cached=bool(tuned.get("tune_cached")),
        # wall-derived (machine-dependent): routed to the info side
        wall_s=tot["wall_s"],
        solves_per_wall_sec=tot["solves_per_wall_sec"],
        warm_solves_per_wall_sec=tot["warm_solves_per_wall_sec"],
        cold_solves_per_wall_sec=tot["cold_solves_per_wall_sec"],
        wall_latency_p50_s=tot["wall_latency_p50_s"],
        wall_latency_p99_s=tot["wall_latency_p99_s"],
    )


def run(shards: int = 2, side: int = 12, requests: int = 16, slots: int = 8,
        maxiter: int = 300, budget: int = 4,
        grid: str | None = None) -> list[dict]:
    rows, legs = [], {}
    grid_extra = ["--grid", grid] if grid else []

    # untuned legs: batched width-`slots` admission vs sequential serving
    for leg, slot_count in (("batched", slots), ("sequential", 1)):
        _, led = run_serve_with_ledger(
            _serve_args(side, shards, requests, slot_count, maxiter,
                        extra=grid_extra),
            n_devices=shards,
        )
        legs[leg] = led
        row = _leg_row(leg, led)
        if grid:
            row["grid"] = grid
        rows.append(row)

    # tuned leg, twice against one cache: invocation 1 pays the trials,
    # invocation 2 must be served entirely from the persistent cache.
    # --grid pins the layout by hand, which excludes the tuner (it owns
    # the layout axis) — grid reruns exercise the untuned legs only.
    if not grid:
        cache_dir = tempfile.mkdtemp(prefix="serve_bench_")
        try:
            cache = os.path.join(cache_dir, "cache.json")
            tuned_args = _serve_args(
                side, shards, requests, slots, maxiter,
                extra=["--autotune", "--objective", "energy",
                       "--tune-budget", str(budget), "--tune-cache", cache],
            )
            for invocation in (1, 2):
                _, led = run_serve_with_ledger(tuned_args, n_devices=shards)
                legs[f"tuned{invocation}"] = led
                rows.append(_leg_row(f"tuned{invocation}", led))
        finally:
            shutil.rmtree(cache_dir, ignore_errors=True)

    # invariant (a): warm requests do zero partitions and zero trials
    for leg, led in legs.items():
        sess = led["sessions"][0]
        assert sess["partitions"] >= 1 and led["cold_batches"] == 1, (
            f"{leg}: expected exactly one cold batch over one partition, "
            f"got {led['cold_batches']} cold / {sess['partitions']} "
            f"partitions"
        )
        for b in led["batches"]:
            if not b["cold"]:
                assert b["new_partitions"] == 0, (
                    f"{leg} batch {b['batch']}: warm batch re-partitioned "
                    f"({b['new_partitions']} new partitions)"
                )
                assert b["new_tune_trials"] == 0, (
                    f"{leg} batch {b['batch']}: warm batch ran "
                    f"{b['new_tune_trials']} tuning trials"
                )
    if not grid:
        t1, t2 = legs["tuned1"], legs["tuned2"]
        assert t1["sessions"][0]["tune_trials"] > 0, (
            "first tuned invocation ran no trials against a fresh cache"
        )
        assert t1["batches"][0]["new_tune_trials"] > 0, (
            "tuned leg did not pay its trials in the cold batch"
        )
        assert not t1["tuned"][0]["tune_cached"], (
            "first tuned invocation claims a cache hit on a fresh cache"
        )
        assert t2["sessions"][0]["tune_trials"] == 0, (
            f"second tuned invocation still ran "
            f"{t2['sessions'][0]['tune_trials']} trials: the tuning cache "
            f"did not serve it"
        )
        assert t2["tuned"][0]["tune_cached"], (
            "second tuned invocation missed the tuning cache"
        )
        assert (
            t2["tuned"][0]["tuned_label"] == t1["tuned"][0]["tuned_label"]
        ), (
            f"cache returned a different config: "
            f"{t2['tuned'][0]['tuned_label']} vs "
            f"{t1['tuned'][0]['tuned_label']}"
        )

    # invariant (b): batched warm throughput >= 2x sequential, and >= 2x
    # the batched leg's own cold throughput
    bt, sq = legs["batched"]["totals"], legs["sequential"]["totals"]
    assert (
        bt["warm_solves_per_wall_sec"]
        >= 2.0 * sq["warm_solves_per_wall_sec"]
    ), (
        f"batched warm rate {bt['warm_solves_per_wall_sec']:.2f}/s is not "
        f"2x the sequential warm rate "
        f"{sq['warm_solves_per_wall_sec']:.2f}/s"
    )
    assert (
        bt["warm_solves_per_wall_sec"]
        >= 2.0 * bt["cold_solves_per_wall_sec"]
    ), (
        f"warm serving {bt['warm_solves_per_wall_sec']:.2f}/s is not 2x "
        f"cold {bt['cold_solves_per_wall_sec']:.2f}/s"
    )

    # invariant (c): per-request energies sum to the engine total
    for leg, led in legs.items():
        tot = led["totals"]
        err = abs(tot["energy_requests_j"] - tot["energy_j"])
        assert err <= 0.05 * tot["energy_j"], (
            f"{leg}: per-request energy sum {tot['energy_requests_j']} "
            f"diverges from the engine total {tot['energy_j']}"
        )
    return rows


def main(smoke: bool = False, grid: str | None = None):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    rows = run(
        shards=2,
        side=10 if smoke else 12,
        requests=16 if smoke else 24,
        maxiter=200 if smoke else 300,
        grid=grid,
    )
    print(fmt_table(
        rows,
        [("leg", "leg"), ("slots", "slots"), ("n_requests", "reqs"),
         ("warm_batches", "warm"), ("session_tune_trials", "trials"),
         ("energy_per_solve_j", "J/solve"),
         ("warm_solves_per_wall_sec", "warm solves/s"),
         ("wall_latency_p99_s", "p99 (s)")],
        "Serving engine: warm-session throughput and per-request energy",
    ))
    # grid reruns land in their own ledger: the canonical 1-D serve_bench
    # baseline stays byte-identical (and gated) regardless
    write_results("serve_bench" if not grid else "serve_bench_grid", rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--grid", default=None,
                    help="RxC process-grid passthrough (R*C must equal the "
                         "benchmark's shard count): reruns the untuned "
                         "serving legs on the 2-D layout; results go to "
                         "the ungated serve_bench_grid ledger")
    a = ap.parse_args()
    main(smoke=a.smoke, grid=a.grid)
