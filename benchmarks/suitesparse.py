"""Tables 7/8 analog: SpMV + CG on the SuiteSparse SPD matrices (1-4 shards).

Synthetic analogs matched on rows/nnz/pattern character (see
matrices/suitesparse.py; real .mtx files are used when
$REPRO_SUITESPARSE_DIR provides them). EXECUTED in subprocesses (real
convergence/iteration behavior) at ``--scale`` of the original sizes, with
modeled energy at the executed sizes.
"""

from __future__ import annotations

from benchmarks.common import parse_solver_output, run_solver_subprocess, write_results
from repro.matrices.suitesparse import TABLE1

MATRICES = list(TABLE1)
SHARDS = (1, 2, 4)


def run(scale: float = 0.01, maxiter: int = 100, matrices=MATRICES,
        shards=SHARDS) -> list[dict]:
    rows = []
    for op in ("spmv", "cg"):
        for name in matrices:
            for s in shards:
                try:
                    out = run_solver_subprocess(
                        ["--problem", name, "--scale", str(scale), "--op", op,
                         "--shards", str(s), "--maxiter", str(maxiter),
                         "--tol", "1e-8"],
                        n_devices=s,
                    )
                except RuntimeError as e:  # pragma: no cover
                    rows.append(dict(table="7/8", op=op, matrix=name,
                                     n_shards=s, error=str(e)[:200]))
                    continue
                parsed = parse_solver_output(out)
                for lib, r in parsed.items():
                    rows.append(
                        dict(
                            table="7" if op == "spmv" else "8",
                            op=op,
                            matrix=name,
                            n_shards=s,
                            library=lib.replace("-analog", ""),
                            wall_s=r["wall_s"],
                            modeled_s=r["modeled_s"],
                            iters=r["iters"],
                            de_gpu=r["de_gpu"],
                            de_cpu=r["de_cpu"],
                            de_total=r["de_total"],
                            gpu_power_peak=r["peak_w"],
                        )
                    )
    write_results("suitesparse", rows)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    rows = run(
        scale=0.004 if smoke else 0.01,
        maxiter=30 if smoke else 100,
        matrices=MATRICES[:1] if smoke else MATRICES,
        shards=(1, 2) if smoke else SHARDS,
    )
    for table, title in (("7", "Table 7 analog: SpMV"), ("8", "Table 8 analog: CG")):
        sel = [r for r in rows if r.get("table") == table and "error" not in r]
        cols = [
            ("n_shards", "#GPUs"), ("matrix", "matrix"), ("library", "library"),
            ("wall_s", "time (s)"), ("de_gpu", "GPU dynE (J)"),
            ("de_cpu", "CPU dynE (J)"), ("de_total", "total dynE (J)"),
            ("gpu_power_peak", "peak (W)"),
        ]
        if table == "8":
            cols.insert(3, ("iters", "iters"))
        print(fmt_table(sel, cols, title))


if __name__ == "__main__":
    main()
