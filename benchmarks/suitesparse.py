"""Tables 7/8 analog: SpMV + CG on the SuiteSparse SPD matrices (1-4 shards),
plus the interior-format sweep (ell/hyb/bcsr/auto — docs/formats.md).

Synthetic analogs matched on rows/nnz/pattern character (see
matrices/suitesparse.py; real .mtx files are used when
$REPRO_SUITESPARSE_DIR provides them). EXECUTED in subprocesses (real
convergence/iteration behavior) at ``--scale`` of the original sizes, with
modeled energy at the executed sizes.

The format sweep runs the distributed SpMV with every interior storage
format on the power-law stress matrix plus SuiteSparse analogs and gates
the stored-bytes / modeled-energy ledger
(``benchmarks/baselines/suitesparse_formats_smoke.json``). It HARD-ASSERTS
the acceptance ordering: on the power-law matrix HYB stores >= 30% fewer
interior bytes than ELL and the ledger's SpMV-region HBM traffic drops with
it; ``auto`` never stores more than ELL.
"""

from __future__ import annotations

from benchmarks.common import (
    parse_solver_output,
    run_api_solve,
    write_results,
)
from repro.api import ProblemSpec, SolverConfig
from repro.matrices.suitesparse import TABLE1

MATRICES = list(TABLE1)
SHARDS = (1, 2, 4)

FORMATS = ("ell", "hyb", "bcsr", "auto")
# power-law stress pattern first (the hard-assert target), then one
# irregular + one banded Table-1 analog
FORMAT_MATRICES = ("powerlaw", "G3_circuit", "af_shell8")


def _spmv_hbm(ledger: dict) -> float:
    """SpMV-attributed HBM bytes of one solver ledger (overlap region when
    the communication-hiding schedule ran, the serial regions otherwise)."""
    regions = ledger["regions"]
    for name in ("overlap", "spmv"):
        if name in regions:
            return regions[name]["hbm_bytes"]
    # single-shard serial path: the interior matvec lands in "other"
    return regions.get("other", {"hbm_bytes": 0.0})["hbm_bytes"]


def run_formats(scale: float = 0.01, matrices=FORMAT_MATRICES,
                shards=(1, 2, 4), formats=FORMATS) -> list[dict]:
    rows = []
    interior = {}  # (matrix, shards, fmt) -> interior stored bytes
    hbm = {}
    for name in matrices:
        for s in shards:
            spec = ProblemSpec(problem=name, scale=scale, shards=s)
            for f in formats:
                _, led = run_api_solve(spec, SolverConfig(op="spmv", fmt=f))
                solver = led["solvers"]["BCMGX-analog"]
                interior[(name, s, f)] = led["interior_stored_bytes"]
                hbm[(name, s, f)] = _spmv_hbm(solver)
                rows.append(
                    dict(
                        table="formats",
                        matrix=name,
                        n_shards=s,
                        format=f,
                        resolved_format=led["resolved_format"],
                        interior_stored_bytes=led["interior_stored_bytes"],
                        stored_bytes=led["stored_bytes"],
                        spmv_hbm_bytes=hbm[(name, s, f)],
                        de_total=solver["totals"]["de_total"],
                        wall_s=solver["wall_s"],
                    )
                )
    # acceptance hard-asserts (power-law matrix, every shard count swept)
    for name in matrices:
        for s in shards:
            e, h = interior[(name, s, "ell")], interior[(name, s, "hyb")]
            a = interior[(name, s, "auto")]
            assert a <= e, (
                f"auto stored MORE than ELL on {name}/{s}: {a} > {e}"
            )
            if name == "powerlaw":
                assert h <= 0.7 * e, (
                    f"HYB saved <30% interior bytes on {name}/{s}: "
                    f"{h} vs {e}"
                )
                assert hbm[(name, s, "hyb")] < hbm[(name, s, "ell")], (
                    f"ledger SpMV HBM did not drop with HYB on {name}/{s}"
                )
    write_results("suitesparse_formats", rows)
    return rows


def run(scale: float = 0.01, maxiter: int = 100, matrices=MATRICES,
        shards=SHARDS) -> list[dict]:
    rows = []
    for op in ("spmv", "cg"):
        for name in matrices:
            for s in shards:
                spec = ProblemSpec(problem=name, scale=scale, shards=s)
                cfg = SolverConfig(op=op, maxiter=maxiter, tol=1e-8)
                try:
                    out, _ = run_api_solve(spec, cfg, ledger=False)
                except RuntimeError as e:  # pragma: no cover
                    rows.append(dict(table="7/8", op=op, matrix=name,
                                     n_shards=s, error=str(e)[:200]))
                    continue
                parsed = parse_solver_output(out)
                for lib, r in parsed.items():
                    rows.append(
                        dict(
                            table="7" if op == "spmv" else "8",
                            op=op,
                            matrix=name,
                            n_shards=s,
                            library=lib.replace("-analog", ""),
                            wall_s=r["wall_s"],
                            modeled_s=r["modeled_s"],
                            iters=r["iters"],
                            de_gpu=r["de_gpu"],
                            de_cpu=r["de_cpu"],
                            de_total=r["de_total"],
                            gpu_power_peak=r["peak_w"],
                        )
                    )
    write_results("suitesparse", rows)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    rows = run(
        scale=0.004 if smoke else 0.01,
        maxiter=30 if smoke else 100,
        matrices=MATRICES[:1] if smoke else MATRICES,
        shards=(1, 2) if smoke else SHARDS,
    )
    fmt_rows = run_formats(
        scale=0.004 if smoke else 0.01,
        matrices=FORMAT_MATRICES[:2] if smoke else FORMAT_MATRICES,
        shards=(2,) if smoke else (1, 2, 4),
    )
    cols = [
        ("matrix", "matrix"), ("n_shards", "#GPUs"), ("format", "format"),
        ("resolved_format", "resolved"),
        ("interior_stored_bytes", "interior (B)"),
        ("spmv_hbm_bytes", "SpMV HBM (B)"), ("de_total", "total dynE (J)"),
    ]
    print(fmt_table(fmt_rows, cols, "Format sweep: interior storage (docs/formats.md)"))
    for table, title in (("7", "Table 7 analog: SpMV"), ("8", "Table 8 analog: CG")):
        sel = [r for r in rows if r.get("table") == table and "error" not in r]
        cols = [
            ("n_shards", "#GPUs"), ("matrix", "matrix"), ("library", "library"),
            ("wall_s", "time (s)"), ("de_gpu", "GPU dynE (J)"),
            ("de_cpu", "CPU dynE (J)"), ("de_total", "total dynE (J)"),
            ("gpu_power_peak", "peak (W)"),
        ]
        if table == "8":
            cols.insert(3, ("iters", "iters"))
        print(fmt_table(sel, cols, title))


if __name__ == "__main__":
    main()
