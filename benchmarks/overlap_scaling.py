"""Communication hiding: exposed vs hidden comm, overlap on/off (§Overlap).

Two views of the overlap layer (core/spmv.py interior/boundary split +
core/cg.py pipecg), mirroring the paper's claim that minimizing *exposed*
data movement drives both time and energy:

* **modeled** — per-iteration communication exposure at the paper's sizes
  across shard counts: the halo exchange's collective time against the
  interior-matvec hide budget (CostModel engine times), plus the all-reduce
  latency term per variant (roofline/analysis.py ``CG_COMM`` — pipecg's
  single reduction is hidden behind the concurrent SpMV, hs/fcg block).
* **executed** — real multi-device solves through ``launch.solve --ledger``
  with the overlap schedule on vs off (``--no-overlap``). HARD-ASSERTS the
  acceptance invariant: on >= 2 devices, ``totals.comm_exposed_s`` is
  strictly lower (and ``comm_hidden_s`` strictly higher) with overlap
  enabled, at identical convergence. The modeled exposure numbers are
  deterministic and land on the ledger's gated side.
"""

from __future__ import annotations

from benchmarks.common import (
    SHARD_COUNTS,
    abstract_poisson_mat,
    run_api_solve,
    write_results,
)
from repro.api import ProblemSpec, SolverConfig

PAPER_SIDE = 405  # 7pt weak-scaled DOFs/device, as in cg_scaling
VARIANTS = ("hs", "pipecg")


def modeled(shard_counts=SHARD_COUNTS, side: int = PAPER_SIDE) -> list[dict]:
    """Per-iteration exposed/hidden comm (seconds) from the cost model."""
    from repro.energy.accounting import CostModel, spmv_counts
    from repro.roofline.analysis import cg_exposed_latency_s

    cost = CostModel()
    rows = []
    for s in shard_counts:
        if s < 2:
            continue
        _, mat = abstract_poisson_mat(side, "7pt", s, weak=True)
        c = spmv_counts(mat)
        _, (tc, tm, tl) = cost.times(c, s, overlap=True)
        hide_budget = max(tc, tm)
        for variant in VARIANTS:
            for overlap in (True, False):
                halo_hidden = min(tl, hide_budget) if overlap else 0.0
                red_exposed = cg_exposed_latency_s(
                    variant, s,
                    alpha=cost.alpha_latency,
                    hide_budget_s=hide_budget if overlap else 0.0,
                )
                red_total = cg_exposed_latency_s(
                    variant, s, alpha=cost.alpha_latency, hide_budget_s=0.0
                )
                rows.append(
                    dict(
                        figure="overlap_modeled",
                        stencil="7pt",
                        n_shards=s,
                        variant=variant,
                        overlap=overlap,
                        dofs=side**3 * s,
                        halo_comm_s=tl,
                        halo_exposed_s=tl - halo_hidden,
                        reduce_exposed_s=red_exposed,
                        comm_exposed_s=(tl - halo_hidden) + red_exposed,
                        comm_hidden_s=halo_hidden + (red_total - red_exposed),
                    )
                )
    return rows


def executed(
    shards=(2, 4), side: int = 16, maxiter: int = 200, tol: float = 1e-8,
    grid: str | None = None,
) -> list[dict]:
    """Real solves, overlap on vs off; asserts the exposure invariant.

    ``grid``: optional RxC passthrough — reruns the executed legs on the
    2-D layout (only shard counts matching R*C run; the exposure
    invariant must hold there too). The pipecg reduction/SpMV overlap
    and the halo/interior overlap are layout-independent claims.
    """
    rows = []
    for s in shards:
        if grid is not None:
            r, c = (int(v) for v in grid.lower().split("x"))
            if r * c != s:
                continue
        spec = ProblemSpec(problem="poisson7", side=side, shards=s)
        for variant in VARIANTS:
            got = {}
            for overlap in (True, False):
                cfg = SolverConfig(
                    variant=variant, overlap=overlap, tol=tol,
                    maxiter=maxiter, grid=grid,
                )
                _, led = run_api_solve(spec, cfg)
                sol = led["solvers"]["BCMGX-analog"]
                tot = sol["totals"]
                got[overlap] = tot
                row_extra = {"grid": grid} if grid else {}
                rows.append(
                    dict(
                        figure="overlap_executed",
                        n_shards=s,
                        variant=variant,
                        overlap=overlap,
                        **row_extra,
                        iters=sol["iters"],
                        relres=sol["relres"],
                        regions=",".join(sorted(sol["regions"])),
                        comm_s=tot["comm_s"],
                        comm_exposed_s=tot["comm_exposed_s"],
                        comm_hidden_s=tot["comm_hidden_s"],
                        de_total=tot["de_total"],
                        wall_s=sol["wall_s"],
                    )
                )
            # acceptance invariant: hiding strictly reduces exposed comm
            assert got[True]["comm_exposed_s"] < got[False]["comm_exposed_s"], (
                f"overlap did not reduce exposed comm ({variant}, {s} shards):"
                f" {got[True]['comm_exposed_s']} !<"
                f" {got[False]['comm_exposed_s']}"
            )
            assert got[True]["comm_hidden_s"] > got[False]["comm_hidden_s"], (
                f"overlap hid no comm ({variant}, {s} shards)"
            )
    return rows


def main(smoke: bool = False, grid: str | None = None):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    mo = modeled(
        shard_counts=(2, 4) if smoke else SHARD_COUNTS,
        side=32 if smoke else PAPER_SIDE,
    )
    print(fmt_table(
        mo,
        [("n_shards", "#GPUs"), ("variant", "variant"),
         ("overlap", "overlap"), ("halo_comm_s", "halo comm (s)"),
         ("comm_exposed_s", "exposed (s)"), ("comm_hidden_s", "hidden (s)")],
        "Modeled per-iteration comm exposure (paper sizes, 7pt weak)",
    ))
    ex = executed(
        shards=(2,) if smoke else (2, 4),
        side=10 if smoke else 16,
        maxiter=80 if smoke else 200,
        grid=grid,
    )
    print(fmt_table(
        ex,
        [("n_shards", "#GPUs"), ("variant", "variant"),
         ("overlap", "overlap"), ("iters", "iters"),
         ("comm_exposed_s", "exposed (s)"), ("comm_hidden_s", "hidden (s)"),
         ("wall_s", "wall (s)")],
        "Executed solves: exposed comm, overlap on vs off",
    ))
    # grid reruns land in their own ledger so the canonical 1-D
    # overlap_scaling baseline stays byte-identical (and gated)
    write_results(
        "overlap_scaling" if not grid else "overlap_scaling_grid", mo + ex
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--grid", default=None,
                    help="RxC process-grid passthrough: reruns the "
                         "executed overlap legs on the 2-D layout (only "
                         "shard counts equal to R*C run); results go to "
                         "the ungated overlap_scaling_grid ledger")
    a = ap.parse_args()
    main(smoke=a.smoke, grid=a.grid)
