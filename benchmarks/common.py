"""Shared benchmark machinery.

Two result kinds, mirroring what this container can and cannot measure:

* **modeled** — paper-scale configurations (405^3/GPU etc.) evaluated through
  the calibrated roofline cost/energy model (energy/accounting.py). Matrices
  are never materialized: the DistMat ShapeDtypeStruct builder supplies the
  exact shapes/halo plans the counts need. These are the scaling curves.
* **executed** — small-scale real runs (subprocess with N host devices)
  giving true iteration counts / convergence and wall times. Wall times on
  CPU are NOT TPU-representative; they validate correctness of the compared
  implementations, while the modeled numbers carry the performance story —
  the same separation the paper makes between time measurements and
  energy-model-derived quantities.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
OUT = os.path.join(REPO, "runs", "bench")
LEDGERS = os.path.join(REPO, "runs", "ledgers")

SHARD_COUNTS = (1, 2, 4, 8, 16, 32, 64)  # the paper's GPU counts

_SMOKE = False

# Row keys that carry measured wall-clock time (machine-dependent): they go
# to the ledger's "info" side, never the gated side. Everything else numeric
# (modeled energy/time, executed iteration counts, op counts) is
# deterministic for a given code version and is gated by CI against the
# checked-in baselines (benchmarks/baselines/*.json, 5% tolerance).
NONDETERMINISTIC_KEYS = (
    "wall_s", "setup_s", "solve_s", "relres", "agree_relerr",
)


def _is_gated(key: str) -> bool:
    return key not in NONDETERMINISTIC_KEYS and "wall" not in key


def set_smoke(on: bool):
    """Smoke-mode runs write '<name>_smoke.csv' so toy-size rows never
    overwrite the canonical full-size result ledger."""
    global _SMOKE
    _SMOKE = bool(on)


def ensure_out():
    os.makedirs(OUT, exist_ok=True)
    return OUT


def abstract_poisson_mat(side: int, stencil: str, n_shards: int, weak: bool,
                         layout: str = "ring"):
    """ShapeDtypeStruct DistMat (ELL interior) at paper scale (no allocation)."""
    from repro.core.cg import abstract_stencil_dist
    from repro.matrices.poisson import PoissonProblem

    nz = side * n_shards if weak else side
    p = PoissonProblem(side, side, nz, stencil)
    mat = abstract_stencil_dist(p, n_shards)
    if layout == "allgather":
        mat = dataclasses.replace(
            mat,
            plan=dataclasses.replace(
                mat.plan, mode="allgather", shifts=(), widths=()
            ),
        )
    return p, mat


def run_solver_subprocess(
    args: list[str], n_devices: int, timeout=1800,
    module: str = "repro.launch.solve",
) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", module, "--devices", str(n_devices)] + args
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"solve failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
    return r.stdout


def run_solver_with_ledger(
    args: list[str], n_devices: int, timeout=1800,
    module: str = "repro.launch.solve",
) -> tuple[str, dict]:
    """Run a driver module with ``--ledger``; returns (stdout, ledger dict).

    The ledger is the driver's executed-energy JSON (per-region counts and
    energies integrated from the region trace — see energy/trace.py).
    """
    import tempfile

    fd, path = tempfile.mkstemp(suffix=".json", prefix="solve_ledger_")
    os.close(fd)
    try:
        out = run_solver_subprocess(
            args + ["--ledger", path], n_devices, timeout=timeout,
            module=module,
        )
        with open(path) as f:
            return out, json.load(f)
    finally:
        os.unlink(path)


def run_api_solve(spec, config, n_devices=None, timeout=1800, ledger=True):
    """Run :func:`repro.api.solve` in an ``n``-device subprocess.

    The typed benchmark entry point: build a ``ProblemSpec`` + a
    ``SolverConfig`` (validated at construction — a config that exists is a
    config that runs) and get ``(stdout, ledger)`` back. A subprocess is
    unavoidable because the device count must be fixed before jax
    initializes; ``to_argv()`` is the round-trip-tested bridge onto the
    ``launch.solve`` CLI adapter (tests/test_api.py), so the flags mean
    exactly what the dataclasses say.
    """
    argv = spec.to_argv() + config.to_argv()
    n = n_devices or spec.shards or 1
    if ledger:
        return run_solver_with_ledger(argv, n, timeout=timeout)
    return run_solver_subprocess(argv, n, timeout=timeout), None


def run_serve_with_ledger(
    args: list[str], n_devices: int, timeout=1800
) -> tuple[str, dict]:
    """Run the serving engine (``launch.serve_solver``) with ``--ledger``."""
    return run_solver_with_ledger(
        args, n_devices, timeout=timeout, module="repro.launch.serve_solver"
    )


def parse_solver_output(out: str) -> dict:
    """Extract per-library lines from launch.solve output."""
    res = {}
    for line in out.splitlines():
        for lib in ("BCMGX-analog", "Ginkgo-analog", "AmgX-analog"):
            if line.startswith(lib):
                parts = dict(
                    kv.split("=") for kv in line.split() if "=" in kv
                )
                res[lib] = {
                    "iters": int(parts["iters"]),
                    "relres": float(parts["relres"]),
                    "wall_s": float(parts["wall"].rstrip("s")),
                    "modeled_s": float(parts["modeled"].rstrip("s")),
                    "de_total": float(parts["DE"].rstrip("J")),
                    "peak_w": float(parts["peak"].rstrip("W")),
                    "de_gpu": float(parts.get("DEgpu", "0J").rstrip("J")),
                    "de_cpu": float(parts.get("DEcpu", "0J").rstrip("J")),
                    "setup_s": float(parts.get("setup", "0s").rstrip("s")),
                    "solve_s": float(parts.get("solve", "0s").rstrip("s")),
                }
    return res


def write_results(name: str, rows: list[dict]):
    """Write the CSV result table AND the machine-readable JSON ledger.

    The ledger splits each row into gated fields (deterministic: modeled
    energy/time, iteration counts — numbers compared against baselines with
    a 5% tolerance, strings exactly) and info fields (measured wall times).
    CI's energy-ledger job regresses the gated side; see
    benchmarks/check_ledgers.py.
    """
    from repro.energy.report import write_csv

    ensure_out()
    if _SMOKE:
        name = f"{name}_smoke"
    path = os.path.join(OUT, f"{name}.csv")
    write_csv(path, rows)
    gate_rows = [
        {k: v for k, v in r.items() if _is_gated(k)} for r in rows
    ]
    info_rows = [
        {k: v for k, v in r.items() if not _is_gated(k)} for r in rows
    ]
    write_ledger(name, gate={"rows": gate_rows}, info={"rows": info_rows})
    return path


def ledger_path(name: str) -> str:
    return os.path.join(LEDGERS, f"{name}.json")


def write_ledger(name: str, gate: dict, info: dict | None = None) -> str:
    """Emit ``runs/ledgers/<name>[_smoke].json``.

    ``gate``: deterministic quantities CI regresses against the checked-in
    baseline (>5% drift fails the energy-ledger job). ``info``: contextual
    data (wall times, environment) that is recorded but never gated.
    """
    from repro.obs.provenance import ledger_meta

    os.makedirs(LEDGERS, exist_ok=True)
    if _SMOKE and not name.endswith("_smoke"):
        name = f"{name}_smoke"
    path = ledger_path(name)
    payload = dict(schema=1, benchmark=name, smoke=_SMOKE, gate=gate,
                   info=info or {}, meta=ledger_meta())
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path
