"""Run every benchmark (one per paper table/figure) and print the tables.

    PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

Modeled scaling tables evaluate at the paper's sizes through the roofline
cost/energy model (no allocation); executed tables run real solves in
multi-device subprocesses at CPU-tractable scales. See benchmarks/common.py
for the modeled/executed distinction.

``--smoke`` executes EVERY benchmark at toy size (tiny shard counts,
shrunken executed problems) so the perf scripts cannot rot silently — CI
runs this mode on every push.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time


BENCHES = [
    ("spmv_scaling (Fig 3)", "benchmarks.spmv_scaling"),
    ("spmv_energy (Fig 4-6, Tab 2-3)", "benchmarks.spmv_energy"),
    ("cg_scaling (Fig 7-10, Tab 4-5)", "benchmarks.cg_scaling"),
    ("pcg_scaling (Fig 11-16, Tab 6)", "benchmarks.pcg_scaling"),
    ("suitesparse (Tab 7-8)", "benchmarks.suitesparse"),
    ("hotpath_fusion (§Perf)", "benchmarks.hotpath_fusion"),
    ("overlap_scaling (§Overlap)", "benchmarks.overlap_scaling"),
    ("strong_scaling (§ScaleOut)", "benchmarks.strong_scaling"),
    ("sstep_scaling (§CommAvoid)", "benchmarks.sstep_scaling"),
    ("multirhs_scaling (§MultiRHS)", "benchmarks.multirhs_scaling"),
    ("autotune_sweep (§Autotune)", "benchmarks.autotune_sweep"),
    ("serve_bench (§Serving)", "benchmarks.serve_bench"),
    ("obs_sampling (§Observability)", "benchmarks.obs_sampling"),
    ("roofline_table (§Roofline)", "benchmarks.roofline_table"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the executed (subprocess) benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="run every benchmark at toy size (CI rot check)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    import importlib

    if args.smoke:
        import glob
        import os

        from benchmarks.common import LEDGERS, set_smoke

        set_smoke(True)
        # full smoke runs drop stale ledgers first so runs/ledgers reflects
        # exactly this run (check_ledgers --update promotes every
        # *_smoke.json it finds); --only keeps the others in place
        if not args.only:
            for stale in glob.glob(os.path.join(LEDGERS, "*_smoke.json")):
                os.unlink(stale)
    failures = []
    for title, modname in BENCHES:
        if args.only and args.only not in modname:
            continue
        if args.fast and not args.smoke and modname in (
            "benchmarks.pcg_scaling", "benchmarks.suitesparse",
            "benchmarks.hotpath_fusion", "benchmarks.overlap_scaling",
            "benchmarks.strong_scaling", "benchmarks.sstep_scaling",
            "benchmarks.multirhs_scaling",
            "benchmarks.autotune_sweep", "benchmarks.serve_bench",
        ):
            print(f"=== {title}: SKIPPED (--fast) ===\n")
            continue
        print(f"\n{'='*72}\n=== {title}\n{'='*72}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(modname)
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.main).parameters:
                kw["smoke"] = True
            mod.main(**kw)
            print(f"[{title}] done in {time.perf_counter()-t0:.1f}s", flush=True)
        except Exception as e:  # pragma: no cover
            import traceback

            failures.append((title, e))
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED: {[f[0] for f in failures]}")
        sys.exit(1)
    print("\nall benchmarks complete.")


if __name__ == "__main__":
    main()
